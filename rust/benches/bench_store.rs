//! Store-subsystem benches: put/get throughput of the replicated KV
//! layer and repair traffic under the Eq. III.1 churn model, reported
//! alongside the maintenance-traffic benches (bench_fig3/4).

use std::time::Duration;

use d1ht::id::Id;
use d1ht::routing::Table;
use d1ht::sim::churn::ChurnCfg;
use d1ht::sim::harness::{run_d1ht_store, ExperimentCfg, Phase};
use d1ht::store::{StoreCfg, StoreLayer};
use d1ht::util::bench::{bench_auto, black_box, run_suite};
use d1ht::util::fmt::{bps, Table as Report};
use d1ht::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(1);
    let mut results = Vec::new();

    // put/get throughput against the paper's largest table (4,000 peers)
    let truth = Table::from_ids((0..4000).map(|_| Id(rng.next_u64())).collect());
    let cfg = StoreCfg { keys: 10_000, ..Default::default() };
    let mut layer = StoreLayer::new(cfg, Rng::new(2));
    layer.preload(&truth);
    results.push(bench_auto("store_1024_zipf_ops_n4000_10k_keys", Duration::from_millis(300), || {
        for _ in 0..1024 {
            layer.workload_step(&truth);
        }
        black_box(layer.counters.puts);
    }));

    // anti-entropy pass over 10k keys after 40 departures
    let survivors: Vec<Id> =
        truth.ids().iter().enumerate().filter(|(i, _)| i % 100 != 0).map(|(_, &id)| id).collect();
    let after = Table::from_ids(survivors);
    results.push(bench_auto("store_repair_pass_10k_keys_40_leaves", Duration::from_millis(300), || {
        let mut l = layer.clone();
        l.repair(&after);
        black_box(l.counters.repair_transfers);
    }));

    run_suite("store (replicated KV hot paths)", results);

    // end-to-end simulated cell: throughput + repair bandwidth under churn
    let cfg = ExperimentCfg {
        target_n: 512,
        churn: ChurnCfg::exponential(174.0 * 60.0),
        growth: Phase::Bootstrap,
        settle_secs: 60.0,
        measure_secs: 240.0,
        seeds: vec![1],
        lookup_rate: 0.0,
        ..Default::default()
    };
    let scfg = StoreCfg { keys: 2000, repair_interval: 30.0, ..Default::default() };
    let res = run_d1ht_store(&cfg, &scfg);
    let mut t = Report::new(
        "simulated storage cell (n=512, Savg=174min, R=3, 240s window)",
        &["metric", "value"],
    );
    t.row(vec!["store ops (sim-time)/s".into(), format!("{:.1}", res.ops_per_sec)]);
    t.row(vec!["puts / gets".into(), format!("{} / {}", res.puts, res.gets)]);
    t.row(vec!["keys retrievable %".into(), format!("{:.3}", res.retrievable * 100.0)]);
    t.row(vec!["get availability %".into(), format!("{:.3}", res.availability * 100.0)]);
    t.row(vec!["repair transfers".into(), (res.repair_transfers + res.handoff_transfers).to_string()]);
    t.row(vec!["repair bandwidth/peer".into(), bps(res.repair_bps_per_peer)]);
    t.row(vec!["store bandwidth/peer".into(), bps(res.store_bps_per_peer)]);
    println!("{}", t.render());
}
