//! Regenerates Figure 6: D1HT latency vs peers-per-node on busy nodes,
//! 200 vs 400 physical nodes.

use d1ht::experiments::{fig6, Fidelity};

fn main() {
    let fid = if std::env::args().any(|a| a == "--paper") {
        Fidelity::Paper
    } else {
        Fidelity::Quick
    };
    let t0 = std::time::Instant::now();
    let t = fig6::run(fid);
    println!("{}", t.render());
    println!("(fig6 regenerated in {:?})", t0.elapsed());
}
