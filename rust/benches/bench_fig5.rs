//! Regenerates Figure 5 (a: idle, b: busy nodes): lookup latencies for
//! D1HT, 1h-Calot, Pastry (+expected) and Dserver at 800..4000 peers.

use d1ht::experiments::{fig5, Fidelity};

fn main() {
    let fid = if std::env::args().any(|a| a == "--paper") {
        Fidelity::Paper
    } else {
        Fidelity::Quick
    };
    for busy in [false, true] {
        let t0 = std::time::Instant::now();
        let t = fig5::run(fid, busy);
        println!("{}", t.render());
        println!("(fig5{} regenerated in {:?})\n", if busy { "b" } else { "a" }, t0.elapsed());
    }
}
