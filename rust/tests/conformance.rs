//! Tier-1 gate for the sim/net conformance harness: replay the golden
//! traces through BOTH runtimes and machine-check the diff, then prove
//! the harness has teeth by arming a replicate-dropping [`FaultPlan`]
//! on the net runtime and demanding a divergence.
//!
//! The socket side spins real UDP peers on loopback with wall-clock
//! settle windows, so these tests are seconds-long by design — they are
//! the cross-runtime ground truth everything else leans on.

use d1ht::conformance::{
    diff_reports, explain, run_trace, run_trace_with_faults, Divergence, Trace, TraceOp, TraceStep,
};
use d1ht::fault::FaultPlan;

const CHURN_ZIPF: &str = include_str!("traces/churn_zipf.json");
const STEADY_SMALL: &str = include_str!("traces/steady_small.json");
const PARTITION_HEAL: &str = include_str!("traces/partition_heal.json");
const RESTART_RECOVERY: &str = include_str!("traces/restart_recovery.json");

#[test]
fn golden_traces_parse_and_validate() {
    let churn = Trace::parse(CHURN_ZIPF).expect("churn_zipf parses");
    assert_eq!(churn.name, "churn_zipf");
    assert_eq!(churn.peers, 6);
    assert_eq!(churn.keys, 32);
    assert!(churn.steps.len() > 100, "meaningful workload");
    let steady = Trace::parse(STEADY_SMALL).expect("steady_small parses");
    assert_eq!(steady.name, "steady_small");
    assert_eq!(steady.peers, 4);
    let ph = Trace::parse(PARTITION_HEAL).expect("partition_heal parses");
    assert_eq!(ph.name, "partition_heal");
    assert_eq!(ph.peers, 8);
    assert_eq!(ph.keys, 24);
    let rr = Trace::parse(RESTART_RECOVERY).expect("restart_recovery parses");
    assert_eq!(rr.name, "restart_recovery");
    assert_eq!(rr.peers, 5);
    assert_eq!(rr.keys, 16);
    assert!(
        rr.steps.iter().any(|s| s.op == TraceOp::Restart),
        "the restart trace actually restarts someone"
    );
}

#[test]
fn steady_small_conforms() {
    let trace = Trace::parse(STEADY_SMALL).unwrap();
    let outcome = run_trace(&trace).expect("both replays complete");
    if let Some(d) = &outcome.divergence {
        panic!("{}", explain(d, &outcome.sim, &outcome.net));
    }
    // no churn, so everything written (minus the removes) survives
    assert!((outcome.sim.durability - 1.0).abs() < 1e-12);
    assert!((outcome.net.durability - 1.0).abs() < 1e-12);
}

#[test]
fn churn_zipf_conforms() {
    let trace = Trace::parse(CHURN_ZIPF).unwrap();
    let outcome = run_trace(&trace).expect("both replays complete");
    if let Some(d) = &outcome.divergence {
        panic!("{}", explain(d, &outcome.sim, &outcome.net));
    }
    assert_eq!(outcome.sim.digest, outcome.net.digest, "retrievable-key digests agree");
    assert!((outcome.sim.availability - 1.0).abs() < 1e-12, "R=3 + settles: nothing lost");
    assert!(outcome.sim.class_bits_out[0] > 0, "sim recorded maintenance traffic");
    assert!(outcome.net.class_bits_out[2] > 0, "net recorded store traffic");
}

/// A workload built to make broken replication impossible to hide: with
/// the fault armed every key lives only on its owner, and failing four
/// of eight peers in sequence loses (in expectation) roughly half the
/// key space. The healthy simulator keeps everything, so the differ
/// must flag it. (One failure would flake: a single peer can own zero
/// of the 32 keys with non-trivial probability — net peer IDs hash from
/// OS-assigned ports.)
fn fault_trace() -> Trace {
    let mut steps = Vec::new();
    for k in 0..32 {
        steps.push(TraceStep { t: 0, op: TraceOp::Put { key: k } });
    }
    steps.push(TraceStep { t: 1, op: TraceOp::Settle });
    for i in 0..4u64 {
        // roster index 1 each time: the roster shifts, so four distinct
        // peers die (live 8 -> 4, never below replication)
        steps.push(TraceStep { t: 2 + i, op: TraceOp::Fail { peer: 1 } });
        steps.push(TraceStep { t: 2 + i, op: TraceOp::Settle });
    }
    for k in 0..32 {
        steps.push(TraceStep { t: 6, op: TraceOp::Get { key: k } });
    }
    steps.push(TraceStep { t: 6, op: TraceOp::Settle });
    let trace = Trace {
        name: "fault_probe".to_string(),
        seed: 13,
        peers: 8,
        keys: 32,
        value_len: 16,
        steps,
    };
    trace.validate().expect("fault trace validates");
    trace
}

/// Two abrupt failures followed by two joins and a full read sweep —
/// the recovery half of a partition: peers vanish, new blood arrives,
/// and every surviving key must still read back identically in both
/// runtimes once the roster heals (R = 3 keeps the sweep lossless).
#[test]
fn partition_heal_conforms() {
    let trace = Trace::parse(PARTITION_HEAL).unwrap();
    let outcome = run_trace(&trace).expect("both replays complete");
    if let Some(d) = &outcome.divergence {
        panic!("{}", explain(d, &outcome.sim, &outcome.net));
    }
    assert_eq!(outcome.sim.digest, outcome.net.digest, "retrievable-key digests agree");
    assert!((outcome.sim.durability - 1.0).abs() < 1e-12, "R=3 + settles: nothing lost");
}

/// Crash + restart with durable storage: the net driver runs every peer
/// on a data dir, kills one, and respawns it on the *same* dir — log
/// replay plus anti-entropy must leave both runtimes agreeing on every
/// get outcome and on the final retrievable-key digest, with nothing
/// lost (R = 3 and the recovered shard both protect the keyset).
#[test]
fn restart_recovery_conforms() {
    let trace = Trace::parse(RESTART_RECOVERY).unwrap();
    let outcome = run_trace(&trace).expect("both replays complete");
    if let Some(d) = &outcome.divergence {
        panic!("{}", explain(d, &outcome.sim, &outcome.net));
    }
    assert_eq!(outcome.sim.digest, outcome.net.digest, "retrievable-key digests agree");
    assert!((outcome.sim.durability - 1.0).abs() < 1e-12, "nothing lost across the restart");
    assert!((outcome.net.durability - 1.0).abs() < 1e-12, "nothing lost across the restart");
}

#[test]
fn broken_replication_is_detected() {
    let trace = fault_trace();
    let plan = FaultPlan::drop_kind("replicate");
    let broken = run_trace_with_faults(&trace, Some(&plan)).expect("replays still complete");
    let d = broken.divergence.expect("broken replication must diverge");
    let text = explain(&d, &broken.sim, &broken.net);
    assert!(
        matches!(
            d,
            Divergence::GetMismatch { .. }
                | Divergence::PresentMismatch { .. }
                | Divergence::TrafficBand { .. }
        ),
        "divergence names the broken surface: {text}"
    );
    assert!(text.contains("conformance FAILED"), "{text}");
    // the reports still diff deterministically on re-compare
    assert_eq!(diff_reports(&broken.sim, &broken.net).as_ref(), Some(&d));
}
