//! Property tests over the paper's formal claims (offline registry has
//! no proptest; `util::rng::Rng` drives randomized cases with fixed
//! seeds — failures print the seed for replay).
//!
//! * Theorem 1: an event acknowledged at TTL=ρ reaches **every** peer
//!   **exactly once** under the EDRA rules (full dissemination replay).
//! * Theorem 2: |{peers whose events p acknowledges with TTL ≥ l}| = 2^(ρ-l).
//! * Consistent hashing: ownership arcs partition the ring.
//! * Routing table: apply/undo event sequences preserve sortedness and
//!   converge to ground truth.

use std::collections::HashMap;

use d1ht::edra::{plan_messages, rho_for};
use d1ht::id::ring::RingView;
use d1ht::id::Id;
use d1ht::proto::messages::Event;
use d1ht::routing::Table;
use d1ht::util::rng::Rng;

/// Replay a full EDRA dissemination synchronously (the §IV-B idealized
/// setting: no delays, synchronized intervals) and count acknowledgments
/// per peer.
///
/// `detector` acknowledges `ev` at TTL=ρ; each interval, every peer that
/// acknowledged events forwards them per Rules 1-8 (plan_messages), and
/// recipients acknowledge at the message TTL.
fn replay_dissemination(ids: &[u64], detector: u64, ev: Event) -> HashMap<Id, u32> {
    let table = Table::from_ids(ids.iter().map(|&x| Id(x)).collect());
    let rho = rho_for(table.len());
    let mut acks: HashMap<Id, u32> = HashMap::new();
    // pending[peer] = events acknowledged in the current interval (ttl)
    let mut pending: Vec<(Id, Vec<(Event, u8)>)> = vec![(Id(detector), vec![(ev, rho)])];
    *acks.entry(Id(detector)).or_insert(0) += 1;
    let mut rounds = 0;
    while !pending.is_empty() {
        rounds += 1;
        assert!(rounds <= rho as u32 + 2, "dissemination must finish in <= rho rounds");
        let mut next: HashMap<Id, Vec<(Event, u8)>> = HashMap::new();
        for (peer, acked) in pending.drain(..) {
            for out in plan_messages(peer, &table, &acked) {
                for e in out.events {
                    *acks.entry(out.target).or_insert(0) += 1;
                    next.entry(out.target).or_default().push((e, out.ttl));
                }
            }
        }
        pending = next.into_iter().collect();
        pending.sort_by_key(|(id, _)| *id); // determinism
    }
    acks
}

#[test]
fn theorem1_exactly_once_full_coverage() {
    let mut rng = Rng::new(0xD1);
    for case in 0..60 {
        let n = 2 + rng.below(120) as usize;
        let mut ids: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
        ids.sort_unstable();
        ids.dedup();
        let detector = ids[rng.below(ids.len() as u64) as usize];
        // a leave event for a peer that is NOT in the ring (it left), as
        // in Figure 1: detector = its successor
        let ev = Event::leave(Id(detector.wrapping_sub(1)));
        let acks = replay_dissemination(&ids, detector, ev);
        assert_eq!(
            acks.len(),
            ids.len(),
            "case {case} (n={}): every peer must acknowledge",
            ids.len()
        );
        for (&peer, &count) in &acks {
            assert_eq!(count, 1, "case {case}: peer {peer} acked {count} times (n={})", ids.len());
        }
    }
}

#[test]
fn theorem1_join_events_too() {
    let mut rng = Rng::new(0xD2);
    for _ in 0..30 {
        let n = 2 + rng.below(90) as usize;
        let mut ids: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
        ids.sort_unstable();
        ids.dedup();
        let detector = ids[rng.below(ids.len() as u64) as usize];
        // join: the new peer IS in the ring already (tables updated)
        let ev = Event::join(Id(detector.wrapping_sub(1)));
        let acks = replay_dissemination(&ids, detector, ev);
        assert_eq!(acks.len(), ids.len());
        assert!(acks.values().all(|&c| c == 1));
    }
}

#[test]
fn theorem2_report_set_sizes() {
    // |S(l)| = 2^(rho - l) where S(l) = peers whose events p acknowledges
    // with TTL >= l. Verified by construction: peer p receives M(l) from
    // pred(p, 2^l); unrolling the recursion, S(l) is the set of peers at
    // clockwise distance < 2^(rho-l)... equivalently, counting which
    // origin peers' detections reach p with TTL >= l.
    let mut rng = Rng::new(0xD3);
    for case in 0..14 {
        // Theorem 2's counting argument tiles the ring with 2^k stretches
        // and is exact when n = 2^rho; for other n the wrap + Rule-8
        // discharge shifts one slot. We assert exactness on power-of-two
        // sizes and a ±1 envelope elsewhere.
        let n = if case < 7 {
            1usize << (2 + case % 5) // 4..64, power of two
        } else {
            4 + rng.below(60) as usize
        };
        let mut ids: Vec<u64> = Vec::new();
        while ids.len() < n {
            ids.push(rng.next_u64());
            ids.sort_unstable();
            ids.dedup();
        }
        let n = ids.len();
        let rho = rho_for(n);
        let table = Table::from_ids(ids.iter().map(|&x| Id(x)).collect());
        let observer = Id(ids[0]);
        // For each possible detector, replay and record the TTL at which
        // the observer acknowledges.
        let mut ttl_of_detection: HashMap<Id, u8> = HashMap::new();
        for &det in &ids {
            let ev = Event::leave(Id(det.wrapping_sub(1)));
            // replay, tracking TTLs seen by observer
            let mut pending: Vec<(Id, Vec<(Event, u8)>)> = vec![(Id(det), vec![(ev, rho)])];
            if Id(det) == observer {
                ttl_of_detection.insert(Id(det), rho);
            }
            while !pending.is_empty() {
                let mut next: HashMap<Id, Vec<(Event, u8)>> = HashMap::new();
                for (peer, acked) in pending.drain(..) {
                    for out in plan_messages(peer, &table, &acked) {
                        if out.target == observer && !out.events.is_empty() {
                            ttl_of_detection.entry(Id(det)).or_insert(out.ttl);
                        }
                        for e in out.events {
                            next.entry(out.target).or_default().push((e, out.ttl));
                        }
                    }
                }
                pending = next.into_iter().collect();
            }
        }
        // Theorem 2: #detectors whose events reach the observer with
        // TTL >= l equals 2^(rho - l) (capped by n).
        for l in 0..=rho {
            let count = ttl_of_detection.values().filter(|&&t| t >= l).count();
            let expect = (1usize << (rho - l)).min(n);
            if n.is_power_of_two() {
                assert_eq!(count, expect, "n={n} rho={rho} l={l}");
            } else {
                // with 2^rho > n the ring has a deficit of (2^rho - n)
                // slots, absorbed by the report-set classes; the count
                // stays within [expect - deficit, expect].
                let deficit = (1usize << rho) - n;
                assert!(
                    count + deficit >= expect && count <= expect,
                    "n={n} rho={rho} l={l}: count {count} expect {expect}"
                );
            }
        }
    }
}

#[test]
fn ownership_partitions_the_ring() {
    let mut rng = Rng::new(0xD4);
    for _ in 0..20 {
        let n = 1 + rng.below(200) as usize;
        let ids: Vec<Id> = (0..n).map(|_| Id(rng.next_u64())).collect();
        let view = RingView::from_ids(ids.clone());
        // every key has exactly one owner, and the owner's predecessor
        // arc contains the key
        for _ in 0..200 {
            let k = Id(rng.next_u64());
            let owner = view.successor(k).expect("non-empty ring");
            let pred = view.pred(owner, 1);
            if view.len() > 1 {
                assert!(
                    k.in_arc(pred, owner) || view.len() == 1,
                    "key {k} owner {owner} pred {pred}"
                );
            }
        }
    }
}

#[test]
fn table_event_sequences_converge_to_truth() {
    let mut rng = Rng::new(0xD5);
    for _ in 0..20 {
        let mut truth = Table::new();
        let mut mine = Table::new();
        let mut live: Vec<Id> = Vec::new();
        // random join/leave walk; apply every event to both tables
        for _ in 0..500 {
            if live.is_empty() || rng.chance(0.6) {
                let id = Id(rng.next_u64());
                let ev = Event::join(id);
                truth.apply(&ev);
                mine.apply(&ev);
                live.push(id);
            } else {
                let idx = rng.below(live.len() as u64) as usize;
                let id = live.swap_remove(idx);
                let ev = Event::leave(id);
                truth.apply(&ev);
                mine.apply(&ev);
            }
            // sortedness invariant
            assert!(mine.ids().windows(2).all(|w| w[0] < w[1]));
        }
        assert_eq!(mine.staleness_vs(&truth), 0.0, "same event stream => same table");
        assert_eq!(mine.len(), live.len());
    }
}

#[test]
fn duplicate_events_are_idempotent() {
    let mut rng = Rng::new(0xD6);
    let mut t = Table::new();
    let ids: Vec<Id> = (0..50).map(|_| Id(rng.next_u64())).collect();
    for &id in &ids {
        assert!(t.apply(&Event::join(id)));
        assert!(!t.apply(&Event::join(id)), "duplicate join detected as stale");
    }
    let snapshot = t.ids().to_vec();
    for &id in &ids {
        t.apply(&Event::join(id));
    }
    assert_eq!(t.ids(), &snapshot[..]);
}
