//! Property tests over the paper's formal claims (offline registry has
//! no proptest; `util::rng::Rng` drives randomized cases with fixed
//! seeds — failures print the seed for replay).
//!
//! * Theorem 1: an event acknowledged at TTL=ρ reaches **every** peer
//!   **exactly once** under the EDRA rules (full dissemination replay).
//! * Theorem 2: |{peers whose events p acknowledges with TTL ≥ l}| = 2^(ρ-l).
//! * Consistent hashing: ownership arcs partition the ring.
//! * Routing table: apply/undo event sequences preserve sortedness and
//!   converge to ground truth.

use std::collections::HashMap;

use d1ht::edra::{plan_messages, rho_for};
use d1ht::id::ring::RingView;
use d1ht::id::Id;
use d1ht::proto::messages::Event;
use d1ht::routing::Table;
use d1ht::util::rng::Rng;

/// Replay a full EDRA dissemination synchronously (the §IV-B idealized
/// setting: no delays, synchronized intervals) and count acknowledgments
/// per peer.
///
/// `detector` acknowledges `ev` at TTL=ρ; each interval, every peer that
/// acknowledged events forwards them per Rules 1-8 (plan_messages), and
/// recipients acknowledge at the message TTL.
fn replay_dissemination(ids: &[u64], detector: u64, ev: Event) -> HashMap<Id, u32> {
    let table = Table::from_ids(ids.iter().map(|&x| Id(x)).collect());
    let rho = rho_for(table.len());
    let mut acks: HashMap<Id, u32> = HashMap::new();
    // pending[peer] = events acknowledged in the current interval (ttl)
    let mut pending: Vec<(Id, Vec<(Event, u8)>)> = vec![(Id(detector), vec![(ev, rho)])];
    *acks.entry(Id(detector)).or_insert(0) += 1;
    let mut rounds = 0;
    while !pending.is_empty() {
        rounds += 1;
        assert!(rounds <= rho as u32 + 2, "dissemination must finish in <= rho rounds");
        let mut next: HashMap<Id, Vec<(Event, u8)>> = HashMap::new();
        for (peer, acked) in pending.drain(..) {
            for out in plan_messages(peer, &table, &acked) {
                for e in out.events {
                    *acks.entry(out.target).or_insert(0) += 1;
                    next.entry(out.target).or_default().push((e, out.ttl));
                }
            }
        }
        pending = next.into_iter().collect();
        pending.sort_by_key(|(id, _)| *id); // determinism
    }
    acks
}

#[test]
fn theorem1_exactly_once_full_coverage() {
    let mut rng = Rng::new(0xD1);
    for case in 0..60 {
        let n = 2 + rng.below(120) as usize;
        let mut ids: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
        ids.sort_unstable();
        ids.dedup();
        let detector = ids[rng.below(ids.len() as u64) as usize];
        // a leave event for a peer that is NOT in the ring (it left), as
        // in Figure 1: detector = its successor
        let ev = Event::leave(Id(detector.wrapping_sub(1)));
        let acks = replay_dissemination(&ids, detector, ev);
        assert_eq!(
            acks.len(),
            ids.len(),
            "case {case} (n={}): every peer must acknowledge",
            ids.len()
        );
        for (&peer, &count) in &acks {
            assert_eq!(count, 1, "case {case}: peer {peer} acked {count} times (n={})", ids.len());
        }
    }
}

#[test]
fn theorem1_join_events_too() {
    let mut rng = Rng::new(0xD2);
    for _ in 0..30 {
        let n = 2 + rng.below(90) as usize;
        let mut ids: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
        ids.sort_unstable();
        ids.dedup();
        let detector = ids[rng.below(ids.len() as u64) as usize];
        // join: the new peer IS in the ring already (tables updated)
        let ev = Event::join(Id(detector.wrapping_sub(1)));
        let acks = replay_dissemination(&ids, detector, ev);
        assert_eq!(acks.len(), ids.len());
        assert!(acks.values().all(|&c| c == 1));
    }
}

#[test]
fn theorem2_report_set_sizes() {
    // |S(l)| = 2^(rho - l) where S(l) = peers whose events p acknowledges
    // with TTL >= l. Verified by construction: peer p receives M(l) from
    // pred(p, 2^l); unrolling the recursion, S(l) is the set of peers at
    // clockwise distance < 2^(rho-l)... equivalently, counting which
    // origin peers' detections reach p with TTL >= l.
    let mut rng = Rng::new(0xD3);
    for case in 0..14 {
        // Theorem 2's counting argument tiles the ring with 2^k stretches
        // and is exact when n = 2^rho; for other n the wrap + Rule-8
        // discharge shifts one slot. We assert exactness on power-of-two
        // sizes and a ±1 envelope elsewhere.
        let n = if case < 7 {
            1usize << (2 + case % 5) // 4..64, power of two
        } else {
            4 + rng.below(60) as usize
        };
        let mut ids: Vec<u64> = Vec::new();
        while ids.len() < n {
            ids.push(rng.next_u64());
            ids.sort_unstable();
            ids.dedup();
        }
        let n = ids.len();
        let rho = rho_for(n);
        let table = Table::from_ids(ids.iter().map(|&x| Id(x)).collect());
        let observer = Id(ids[0]);
        // For each possible detector, replay and record the TTL at which
        // the observer acknowledges.
        let mut ttl_of_detection: HashMap<Id, u8> = HashMap::new();
        for &det in &ids {
            let ev = Event::leave(Id(det.wrapping_sub(1)));
            // replay, tracking TTLs seen by observer
            let mut pending: Vec<(Id, Vec<(Event, u8)>)> = vec![(Id(det), vec![(ev, rho)])];
            if Id(det) == observer {
                ttl_of_detection.insert(Id(det), rho);
            }
            while !pending.is_empty() {
                let mut next: HashMap<Id, Vec<(Event, u8)>> = HashMap::new();
                for (peer, acked) in pending.drain(..) {
                    for out in plan_messages(peer, &table, &acked) {
                        if out.target == observer && !out.events.is_empty() {
                            ttl_of_detection.entry(Id(det)).or_insert(out.ttl);
                        }
                        for e in out.events {
                            next.entry(out.target).or_default().push((e, out.ttl));
                        }
                    }
                }
                pending = next.into_iter().collect();
            }
        }
        // Theorem 2: #detectors whose events reach the observer with
        // TTL >= l equals 2^(rho - l) (capped by n).
        for l in 0..=rho {
            let count = ttl_of_detection.values().filter(|&&t| t >= l).count();
            let expect = (1usize << (rho - l)).min(n);
            if n.is_power_of_two() {
                assert_eq!(count, expect, "n={n} rho={rho} l={l}");
            } else {
                // with 2^rho > n the ring has a deficit of (2^rho - n)
                // slots, absorbed by the report-set classes; the count
                // stays within [expect - deficit, expect].
                let deficit = (1usize << rho) - n;
                assert!(
                    count + deficit >= expect && count <= expect,
                    "n={n} rho={rho} l={l}: count {count} expect {expect}"
                );
            }
        }
    }
}

#[test]
fn ownership_partitions_the_ring() {
    let mut rng = Rng::new(0xD4);
    for _ in 0..20 {
        let n = 1 + rng.below(200) as usize;
        let ids: Vec<Id> = (0..n).map(|_| Id(rng.next_u64())).collect();
        let view = RingView::from_ids(ids.clone());
        // every key has exactly one owner, and the owner's predecessor
        // arc contains the key
        for _ in 0..200 {
            let k = Id(rng.next_u64());
            let owner = view.successor(k).expect("non-empty ring");
            let pred = view.pred(owner, 1);
            if view.len() > 1 {
                assert!(
                    k.in_arc(pred, owner) || view.len() == 1,
                    "key {k} owner {owner} pred {pred}"
                );
            }
        }
    }
}

#[test]
fn table_event_sequences_converge_to_truth() {
    let mut rng = Rng::new(0xD5);
    for _ in 0..20 {
        let mut truth = Table::new();
        let mut mine = Table::new();
        let mut live: Vec<Id> = Vec::new();
        // random join/leave walk; apply every event to both tables
        for _ in 0..500 {
            if live.is_empty() || rng.chance(0.6) {
                let id = Id(rng.next_u64());
                let ev = Event::join(id);
                truth.apply(&ev);
                mine.apply(&ev);
                live.push(id);
            } else {
                let idx = rng.below(live.len() as u64) as usize;
                let id = live.swap_remove(idx);
                let ev = Event::leave(id);
                truth.apply(&ev);
                mine.apply(&ev);
            }
            // sortedness invariant
            assert!(mine.ids().windows(2).all(|w| w[0] < w[1]));
        }
        assert_eq!(mine.staleness_vs(&truth), 0.0, "same event stream => same table");
        assert_eq!(mine.len(), live.len());
    }
}

#[test]
fn duplicate_events_are_idempotent() {
    let mut rng = Rng::new(0xD6);
    let mut t = Table::new();
    let ids: Vec<Id> = (0..50).map(|_| Id(rng.next_u64())).collect();
    for &id in &ids {
        assert!(t.apply(&Event::join(id)));
        assert!(!t.apply(&Event::join(id)), "duplicate join detected as stale");
    }
    let snapshot = t.ids().to_vec();
    for &id in &ids {
        t.apply(&Event::join(id));
    }
    assert_eq!(t.ids(), &snapshot[..]);
}

// ---------------------------------------------------------------------------
// Codec conformance: every message variant of both codecs round-trips
// exactly, and seeded byte-mutation / truncation of valid frames makes
// decode *error*, never panic (ISSUE 7 satellite). The variant lists
// below must stay exhaustive — add a line here when adding a variant.
// ---------------------------------------------------------------------------

use std::net::{Ipv4Addr, SocketAddrV4};

use d1ht::net::wire::{self, NetMsg};
use d1ht::proto::codec;
use d1ht::proto::messages::{Message, MessageBody};

fn addr(p: u16) -> SocketAddrV4 {
    SocketAddrV4::new(Ipv4Addr::new(10, 1, (p >> 8) as u8, p as u8), p)
}

/// One instance of every `MessageBody` variant (plus flag-bearing
/// sub-shapes: non-default-port events, found/not-found responses).
fn all_message_bodies() -> Vec<MessageBody> {
    let mut custom_port = Event::join(Id(3));
    custom_port.default_port = false;
    vec![
        MessageBody::Maintenance {
            ttl: 5,
            events: vec![Event::join(Id(1)), Event::leave(Id(u64::MAX)), custom_port],
        },
        MessageBody::CalotMaintenance { event: Event::leave(Id(5)), range: 1 << 40 },
        MessageBody::Ack { of_seqno: 99 },
        MessageBody::Heartbeat,
        MessageBody::Lookup { target: Id(123) },
        MessageBody::LookupResp { target: Id(1), owner: Id(2), terminal: true },
        MessageBody::JoinRequest { joiner: Id(77) },
        MessageBody::TableTransfer { ids: (0..100).map(Id).collect() },
        MessageBody::Probe,
        MessageBody::ProbeReply,
        MessageBody::Put { key: Id(9), value_bits: 1024 },
        MessageBody::Get { key: Id(9) },
        MessageBody::Remove { key: Id(9) },
        MessageBody::GetResp { key: Id(9), found: true, value_bits: 512 },
        MessageBody::GetResp { key: Id(10), found: false, value_bits: 0 },
        MessageBody::Replicate { key: Id(9), version: 7, value_bits: 64 },
        MessageBody::Handoff { keys: vec![(Id(1), 8), (Id(2), 16)] },
    ]
}

/// One instance of every `NetMsg` variant (all 23 wire tags, plus the
/// tombstone/empty sub-shapes that exercise optional payload paths).
fn all_net_msgs() -> Vec<NetMsg> {
    vec![
        NetMsg::Maintenance { seq: 7, ttl: 3, joins: vec![addr(1), addr(2)], leaves: vec![addr(9)] },
        NetMsg::Ack { of_seq: 12 },
        NetMsg::Lookup { nonce: 5, target: u64::MAX },
        NetMsg::LookupResp { nonce: 5, owner: addr(42) },
        NetMsg::JoinReq { joiner: addr(4000) },
        NetMsg::Table { seq: 1, addrs: (0..100).map(addr).collect() },
        NetMsg::LeaveNotice { seq: 2, leaver: addr(8) },
        NetMsg::Probe { nonce: 3 },
        NetMsg::ProbeReply { nonce: 3 },
        NetMsg::Put { nonce: 4, key: u64::MAX, value: vec![1, 2, 3] },
        NetMsg::PutResp { nonce: 4, ok: true },
        NetMsg::Get { nonce: 5, key: 99 },
        NetMsg::GetResp { nonce: 5, found: true, version: 7, value: vec![9; 64] },
        NetMsg::GetResp { nonce: 6, found: false, version: 0, value: vec![] },
        NetMsg::Remove { nonce: 7, key: 123 },
        NetMsg::RemoveResp { nonce: 7, ok: false },
        NetMsg::Replicate { seq: 8, key: 1, version: 2, tombstone: false, value: vec![0xAB; 16] },
        NetMsg::Replicate { seq: 10, key: 1, version: 3, tombstone: true, value: vec![] },
        NetMsg::Handoff { seq: 9, pairs: vec![(1, 1, false, vec![1]), (2, 3, true, vec![])] },
        NetMsg::BulkOffer {
            seq: 11,
            id: u64::MAX,
            kind: 2,
            total: 1 << 33,
            crc: 0xDEAD_BEEF_CAFE_F00D,
            tcp_port: 40001,
        },
        NetMsg::BulkAccept { id: 7, from: 65_508 },
        NetMsg::BulkData { id: 7, offset: 1 << 20, crc: 0xABCD_1234, bytes: vec![9; 1200] },
        NetMsg::BulkAck { id: 7, next: 1 << 21 },
        NetMsg::BulkNack { id: 7, from: 0 },
        NetMsg::BulkDone { seq: 12, id: 7, ok: true },
        NetMsg::BulkDone { seq: 13, id: 8, ok: false },
    ]
}

#[test]
fn proto_codec_roundtrips_every_variant() {
    let mut rng = Rng::new(0xD7);
    for body in all_message_bodies() {
        let m = Message {
            from: Id(rng.next_u64()),
            to: Id(rng.next_u64()),
            seqno: rng.below(1 << 32) as u32,
            body,
        };
        let dec = codec::decode(&codec::encode(&m)).expect("valid frame decodes");
        assert_eq!(m, dec);
    }
}

#[test]
fn net_wire_roundtrips_every_variant() {
    for m in all_net_msgs() {
        let dec = wire::decode(&wire::encode(&m)).expect("valid frame decodes");
        assert_eq!(m, dec);
    }
}

/// Flip 1-4 random bytes (and try a random truncation) of every valid
/// frame, many times: decode must return `Ok` or `Err`, never panic,
/// and a frame with a damaged SystemID word must always be rejected.
#[test]
fn proto_codec_survives_seeded_mutation() {
    let mut rng = Rng::new(0xD8);
    for body in all_message_bodies() {
        let m = Message { from: Id(11), to: Id(22), seqno: 33, body };
        let frame = codec::encode(&m);
        for _ in 0..64 {
            let mut buf = frame.clone();
            for _ in 0..(1 + rng.below(4)) {
                let i = rng.below(buf.len() as u64) as usize;
                buf[i] ^= (1 + rng.below(255)) as u8;
            }
            let _ = codec::decode(&buf); // corrupt: any Result, no panic
            let cut = rng.below(frame.len() as u64 + 1) as usize;
            let _ = codec::decode(&frame[..cut]); // truncated: no panic
        }
        let mut bad_sys = frame.clone();
        bad_sys[7] ^= 0xFF;
        assert!(codec::decode(&bad_sys).is_err(), "foreign SystemID rejected");
    }
}

#[test]
fn net_wire_survives_seeded_mutation() {
    let mut rng = Rng::new(0xD9);
    for m in all_net_msgs() {
        let frame = wire::encode(&m);
        for _ in 0..64 {
            let mut buf = frame.clone();
            for _ in 0..(1 + rng.below(4)) {
                let i = rng.below(buf.len() as u64) as usize;
                buf[i] ^= (1 + rng.below(255)) as u8;
            }
            let _ = wire::decode(&buf); // corrupt: any Result, no panic
            let cut = rng.below(frame.len() as u64 + 1) as usize;
            let _ = wire::decode(&frame[..cut]); // truncated: no panic
        }
        let mut bad_sys = frame.clone();
        bad_sys[7] ^= 0xFF;
        assert!(wire::decode(&bad_sys).is_err(), "foreign SystemID rejected");
    }
}
