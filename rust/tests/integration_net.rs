//! End-to-end over the REAL socket runtime: boots genuine UDP peers on
//! loopback (threads, reliable-UDP, EDRA), exercises joins, lookups,
//! graceful leaves, SIGKILL-style failures, and the bulk-transfer
//! channel (routing-table transfer + key handoff beyond datagram size).

use std::time::Duration;

use d1ht::net::{Cluster, NetPeerCfg};

/// The payload bound the bulk channel removed: max UDP payload bytes.
const OLD_DATAGRAM_BOUND: usize = 65_507;

#[test]
fn cluster_converges_and_resolves() {
    let cluster = Cluster::start(12, 0.01).expect("start");
    assert!(cluster.await_convergence(Duration::from_secs(20)), "convergence");
    let rep = cluster.run_lookups(300, 42);
    assert_eq!(rep.lookups, 300);
    assert!(rep.one_hop_ratio() > 0.99, "one-hop {}", rep.one_hop_ratio());
    assert!(rep.resolved >= 297, "resolved {}", rep.resolved);
    // loopback one-hop latency should be well under a millisecond p50
    let p50 = rep.latency.quantile_ns(0.5);
    assert!(p50 < 300_000_000, "p50 {} ns", p50);
    cluster.shutdown();
}

#[test]
fn survives_kill_and_graceful_leave() {
    let mut cluster = Cluster::start(10, 0.01).expect("start");
    assert!(cluster.await_convergence(Duration::from_secs(20)));
    let removed = cluster.churn_step(7); // one kill + one graceful leave
    assert_eq!(removed, 2);
    std::thread::sleep(Duration::from_secs(2)); // detection + dissemination
    let rep = cluster.run_lookups(200, 3);
    let resolve_rate = rep.resolved as f64 / rep.lookups.max(1) as f64;
    assert!(resolve_rate > 0.99, "resolve rate {resolve_rate}");
    cluster.shutdown();
}

#[test]
fn late_joiner_gets_full_table() {
    let cluster = Cluster::start(6, 0.01).expect("start");
    assert!(cluster.await_convergence(Duration::from_secs(15)));
    // join one more through the founder
    let extra = d1ht::net::peer::spawn(NetPeerCfg {
        bootstrap: Some(cluster.peers[0].addr),
        ..Default::default()
    })
    .expect("late joiner");
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    let mut size = 0;
    while std::time::Instant::now() < deadline {
        size = extra.stats().map(|s| s.table_size).unwrap_or(0);
        if size == 7 {
            break;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    assert_eq!(size, 7, "late joiner table");
    extra.leave();
    cluster.shutdown();
}

/// ISSUE 2 acceptance: a join whose key handoff is ≥ 4× the old
/// single-datagram bound completes via the bulk channel, end-to-end
/// over real sockets, and the joiner serves the values afterwards.
#[test]
fn join_with_oversized_handoff_streams_via_bulk() {
    // R ≥ cluster size ⇒ every peer replicates every key, so the
    // admitting successor must hand the joiner the full key set
    let mk = |bootstrap| NetPeerCfg { replication: 8, bootstrap, ..Default::default() };
    let boot = d1ht::net::peer::spawn(mk(None)).expect("boot");
    let boot_addr = boot.addr;
    let mut peers = vec![boot];
    for _ in 0..2 {
        std::thread::sleep(Duration::from_millis(150));
        peers.push(d1ht::net::peer::spawn(mk(Some(boot_addr))).expect("join"));
    }
    std::thread::sleep(Duration::from_millis(1500));
    // 8 values × 33 KiB = 264 KiB of handoff payload — each value still
    // fits a Put datagram, but the handoff of all of them cannot fit
    // any datagram (≥ 4 × 65,507 B)
    let value_len = 33 * 1024;
    let keys: Vec<u64> = (1u64..=8).map(|k| k.wrapping_mul(0x9E37_79B9_7F4A_7C15)).collect();
    assert!(keys.len() * value_len >= 4 * OLD_DATAGRAM_BOUND);
    for (i, &k) in keys.iter().enumerate() {
        let origin = &peers[i % peers.len()];
        assert!(origin.put(k, vec![i as u8; value_len]).expect("put"), "put {i} confirmed");
    }
    // join a fourth peer: table + 264 KiB handoff stream through bulk
    let joiner = d1ht::net::peer::spawn(mk(Some(boot_addr))).expect("late joiner");
    let deadline = std::time::Instant::now() + Duration::from_secs(15);
    let mut stats = joiner.stats().expect("stats");
    while std::time::Instant::now() < deadline
        && !(stats.table_size == 4 && stats.keys_stored == keys.len() && stats.bulk_recvs_ok >= 2)
    {
        std::thread::sleep(Duration::from_millis(50));
        stats = joiner.stats().expect("stats");
    }
    assert_eq!(stats.table_size, 4, "routing table transferred");
    assert_eq!(stats.keys_stored, keys.len(), "full key range handed off");
    assert!(stats.bulk_recvs_ok >= 2, "table + handoff rode the bulk channel: {stats:?}");
    assert!(
        stats.bulk_bytes_in as usize >= keys.len() * value_len,
        "bulk payload exceeded any datagram: {} bytes",
        stats.bulk_bytes_in
    );
    // the joiner serves the handed-off values itself
    for (i, &k) in keys.iter().enumerate() {
        let got = joiner.get(k).expect("get");
        assert_eq!(got.as_deref(), Some(vec![i as u8; value_len].as_slice()), "value {i}");
    }
    joiner.kill();
    for p in peers {
        p.kill();
    }
}

/// ISSUE 2 acceptance: a routing-table transfer far beyond datagram
/// size survives the sender being killed mid-transfer — the restarted
/// sender resumes from the receiver's last acked offset instead of
/// restarting from zero.
#[test]
fn oversized_table_transfer_resumes_after_interruption() {
    use d1ht::config::BulkTuning;
    use d1ht::net::transport::Transport;
    use d1ht::net::{BulkEndpoint, BulkPayload};
    use std::net::{Ipv4Addr, SocketAddrV4};
    use std::time::Instant;

    let tuning = BulkTuning {
        frame_bytes: 8192,
        window_frames: 4,
        resume_retries: 40,
        stall: Duration::from_millis(30),
        ack_every: 2,
        use_tcp: true,
    };
    let mut ta = Transport::bind_local().expect("ta");
    let mut tb = Transport::bind_local().expect("tb");
    let mut sender = BulkEndpoint::new(tuning);
    let mut receiver = BulkEndpoint::new(tuning);
    // 50,000 members × 6 B ≈ 300 KB — ~4.6× the single-datagram bound
    let addrs: Vec<SocketAddrV4> = (0..50_000u32)
        .map(|i| {
            SocketAddrV4::new(
                Ipv4Addr::new(10, (i >> 16) as u8, (i >> 8) as u8, i as u8),
                5000 + (i % 1000) as u16,
            )
        })
        .collect();
    let table = BulkPayload::Table { addrs };
    let total = table.encode().len();
    assert!(total >= 4 * OLD_DATAGRAM_BOUND);

    let turn = |tr: &mut Transport, ep: &mut BulkEndpoint| {
        let msgs = tr.poll();
        for (from, m) in msgs {
            ep.handle(tr, from, &m);
        }
        ep.pump(tr);
        tr.tick_retransmit();
    };

    sender.start(&mut ta, tb.addr(), &table);
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        turn(&mut ta, &mut sender);
        turn(&mut tb, &mut receiver);
        let partial =
            receiver.recv_progress().first().map(|&(_, got, _)| got > 60_000).unwrap_or(false);
        if partial {
            break;
        }
        assert!(Instant::now() < deadline, "transfer never progressed");
        std::thread::sleep(Duration::from_millis(1));
    }
    assert!(receiver.take_ready().is_empty(), "must be interrupted mid-transfer");
    // kill the sender (listener, serve connections, all transfer state)
    drop(sender);
    // restart: same payload + destination ⇒ same content-addressed id,
    // so the receiver's partial state resumes from its acked offset
    let mut sender2 = BulkEndpoint::new(tuning);
    sender2.start(&mut ta, tb.addr(), &table);
    let deadline = Instant::now() + Duration::from_secs(20);
    let mut got = Vec::new();
    while got.is_empty() {
        turn(&mut ta, &mut sender2);
        turn(&mut tb, &mut receiver);
        got = receiver.take_ready();
        assert!(Instant::now() < deadline, "transfer never completed after restart");
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(got[0].1, table, "table byte-identical after resume");
    assert!(sender2.counters.resumes >= 1, "receiver reported a nonzero resume offset");
    assert!(
        (sender2.counters.data_bytes_sent as usize) < total,
        "resumed, not restarted: {} of {} bytes re-sent",
        sender2.counters.data_bytes_sent,
        total
    );
}
