//! End-to-end over the REAL socket runtime: boots genuine UDP peers on
//! loopback (threads, reliable-UDP, EDRA), exercises joins, lookups,
//! graceful leaves and SIGKILL-style failures.

use std::time::Duration;

use d1ht::net::{Cluster, NetPeerCfg};

#[test]
fn cluster_converges_and_resolves() {
    let cluster = Cluster::start(12, 0.01).expect("start");
    assert!(cluster.await_convergence(Duration::from_secs(20)), "convergence");
    let rep = cluster.run_lookups(300, 42);
    assert_eq!(rep.lookups, 300);
    assert!(rep.one_hop_ratio() > 0.99, "one-hop {}", rep.one_hop_ratio());
    assert!(rep.resolved >= 297, "resolved {}", rep.resolved);
    // loopback one-hop latency should be well under a millisecond p50
    let p50 = rep.latency.quantile_ns(0.5);
    assert!(p50 < 300_000_000, "p50 {} ns", p50);
    cluster.shutdown();
}

#[test]
fn survives_kill_and_graceful_leave() {
    let mut cluster = Cluster::start(10, 0.01).expect("start");
    assert!(cluster.await_convergence(Duration::from_secs(20)));
    let removed = cluster.churn_step(7); // one kill + one graceful leave
    assert_eq!(removed, 2);
    std::thread::sleep(Duration::from_secs(2)); // detection + dissemination
    let rep = cluster.run_lookups(200, 3);
    let resolve_rate = rep.resolved as f64 / rep.lookups.max(1) as f64;
    assert!(resolve_rate > 0.99, "resolve rate {resolve_rate}");
    cluster.shutdown();
}

#[test]
fn late_joiner_gets_full_table() {
    let cluster = Cluster::start(6, 0.01).expect("start");
    assert!(cluster.await_convergence(Duration::from_secs(15)));
    // join one more through the founder
    let extra = d1ht::net::peer::spawn(NetPeerCfg {
        bootstrap: Some(cluster.peers[0].addr),
        ..Default::default()
    })
    .expect("late joiner");
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    let mut size = 0;
    while std::time::Instant::now() < deadline {
        size = extra.stats().map(|s| s.table_size).unwrap_or(0);
        if size == 7 {
            break;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    assert_eq!(size, 7, "late joiner table");
    extra.leave();
    cluster.shutdown();
}
