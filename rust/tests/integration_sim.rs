//! Cross-module integration: simulated systems vs analytical models vs
//! the paper's claims, at reduced (CI-friendly) scale.

use d1ht::analysis::{calot::CalotModel, d1ht::D1htModel};
use d1ht::dht::d1ht::{D1htCfg, D1htSim, Ev};
use d1ht::sim::churn::ChurnCfg;
use d1ht::sim::engine::{run_until, Queue};
use d1ht::sim::harness::{run_calot, run_d1ht, ExperimentCfg, Phase};
use d1ht::sim::network::NetModel;

fn cfg(n: usize, savg_mins: f64, measure: f64) -> ExperimentCfg {
    ExperimentCfg {
        target_n: n,
        churn: ChurnCfg::exponential(savg_mins * 60.0),
        growth: Phase::Bootstrap,
        settle_secs: 120.0,
        measure_secs: measure,
        seeds: vec![1],
        lookup_rate: 1.0,
        ..Default::default()
    }
}

/// §VII headline: >99% one-hop under churn, and the measured bandwidth
/// validates the analysis (Figs. 3-4 "the analyses for both DHTs ...
/// were able to predict their bandwidth demands").
#[test]
fn d1ht_simulation_validates_analysis() {
    let c = cfg(1000, 174.0, 600.0);
    let r = run_d1ht(&c);
    assert!(r.one_hop_ratio > 0.99, "one-hop {}", r.one_hop_ratio);
    let model = D1htModel { delta_avg: NetModel::Hpc.delta_avg(), ..Default::default() }
        .bandwidth_bps(r.n as f64, 174.0 * 60.0);
    let ratio = r.per_peer_bps / model;
    assert!(
        (0.5..2.0).contains(&ratio),
        "measured {} vs model {model} (x{ratio:.2})",
        r.per_peer_bps
    );
}

#[test]
fn calot_simulation_validates_analysis() {
    let c = cfg(1000, 174.0, 600.0);
    let r = run_calot(&c);
    assert!(r.one_hop_ratio > 0.99, "one-hop {}", r.one_hop_ratio);
    let model = CalotModel.bandwidth_bps(r.n as f64, 174.0 * 60.0);
    let ratio = r.per_peer_bps / model;
    assert!(
        (0.5..2.0).contains(&ratio),
        "measured {} vs model {model} (x{ratio:.2})",
        r.per_peer_bps
    );
}

/// Fig. 4 shape at reduced scale: D1HT's advantage grows with churn.
#[test]
fn faster_churn_costs_more_everywhere() {
    let slow = run_d1ht(&cfg(512, 174.0, 400.0));
    let fast = run_d1ht(&cfg(512, 60.0, 400.0));
    assert!(fast.per_peer_bps > slow.per_peer_bps);
}

/// PlanetLab environment: message loss + WAN delays must not break the
/// one-hop bound (Fig. 3 ran there and still saw >99%).
#[test]
fn planetlab_environment_still_one_hop() {
    let mut c = cfg(600, 174.0, 600.0);
    c.net = NetModel::PlanetLab;
    let r = run_d1ht(&c);
    assert!(r.one_hop_ratio > 0.99, "one-hop {}", r.one_hop_ratio);
}

/// §VII-A growth phase stress: doubling in 8 seconds from 8 peers while
/// already churning; the system must stay consistent and keep resolving.
#[test]
fn growth_phase_stress() {
    let mut c = cfg(300, 174.0, 300.0);
    c.growth = Phase::Growth;
    let r = run_d1ht(&c);
    assert!(r.n >= 250, "reached {}", r.n);
    assert!(r.one_hop_ratio > 0.98, "one-hop {}", r.one_hop_ratio);
}

/// Failure injection: kill a contiguous run of peers at once (worst case
/// for successor-based detection) and verify the system re-converges.
#[test]
fn mass_failure_recovery() {
    let cfg = D1htCfg {
        churn: ChurnCfg::exponential(174.0 * 60.0),
        lookup_rate: 2.0,
        ..Default::default()
    };
    let mut sim = D1htSim::new(cfg);
    let mut q = Queue::new();
    sim.bootstrap(256, &mut q);
    run_until(&mut sim, &mut q, 60.0);
    // kill 20 peers simultaneously (SessionEnd events at the same time;
    // half will be failure-style)
    let victims: Vec<_> = sim.truth().ids().iter().take(20).copied().collect();
    for v in victims {
        q.at(61.0, Ev::SessionEnd { peer: v });
    }
    run_until(&mut sim, &mut q, 61.0);
    // let detection + dissemination + rejoins settle
    run_until(&mut sim, &mut q, 600.0);
    sim.begin_recording(q.now());
    sim.start_lookups(&mut q);
    let t1 = q.now() + 300.0;
    run_until(&mut sim, &mut q, t1);
    sim.end_recording(q.now());
    let m = sim.metrics();
    assert!(m.one_hop_ratio() > 0.985, "post-mass-failure one-hop {}", m.one_hop_ratio());
}

/// §V end-to-end: a quarantined joiner is invisible to the overlay until
/// promoted — it enters no routing table, triggers no join event, and
/// receives no maintenance traffic; only after T_q does it join and
/// start receiving keepalives.
#[test]
fn quarantine_gate_blocks_joiners_until_promoted() {
    let tq = 600.0;
    let cfg = D1htCfg {
        churn: ChurnCfg::none(), // isolate the admission gate itself
        quarantine_tq: Some(tq),
        lookup_rate: 0.0,
        ..Default::default()
    };
    let mut sim = D1htSim::new(cfg);
    let mut q = Queue::new();
    sim.bootstrap(48, &mut q);
    sim.begin_recording(0.0);
    let initial: std::collections::BTreeSet<_> =
        sim.truth().ids().iter().copied().collect();
    for i in 0..16 {
        q.at(1.0 + i as f64, Ev::Arrive { label: u64::MAX });
    }
    // run to just before T_q: arrivals must be fully invisible
    run_until(&mut sim, &mut q, tq - 10.0);
    assert_eq!(sim.size(), 48, "no arrival entered the overlay before T_q");
    let known = sim.all_known_ids();
    assert!(
        known.iter().all(|id| initial.contains(id)),
        "a quarantined joiner leaked into a routing table"
    );
    let msgs_before = sim.metrics().maintenance.msgs_in;
    assert!(msgs_before > 0, "maintenance keepalives flow among members");
    // past T_q the survivors are promoted, announced, and fed
    run_until(&mut sim, &mut q, tq + 400.0);
    assert_eq!(sim.size(), 48 + 16, "all survivors promoted after T_q");
    let promoted: Vec<_> = sim
        .maintenance_msgs_in_by_peer()
        .into_iter()
        .filter(|(id, _)| !initial.contains(id))
        .collect();
    assert_eq!(promoted.len(), 16);
    assert!(
        promoted.iter().all(|&(_, msgs_in)| msgs_in > 0),
        "every promoted peer receives maintenance traffic: {promoted:?}"
    );
    let known = sim.all_known_ids();
    assert!(
        known.len() >= 48 + 16,
        "promoted peers announced into routing tables"
    );
}

/// The Quarantine mechanism reduces measured maintenance traffic under
/// heavy-tailed churn (Fig. 8's simulated counterpart).
#[test]
fn quarantine_reduces_measured_traffic() {
    let (plain, quarantined, reduction) =
        d1ht::experiments::fig8::simulate_reduction(768, 5);
    assert!(plain > 0.0);
    assert!(
        reduction > 0.05,
        "reduction {reduction} (plain {plain}, quarantined {quarantined})"
    );
}

/// CPU/memory claims (§VII-C, §VI): routing-table memory ~8B/peer here
/// (paper: 6B); a 4,000-peer table fits in tens of KB.
#[test]
fn memory_footprint_matches_paper_scale() {
    use d1ht::id::Id;
    use d1ht::routing::Table;
    let t = Table::from_ids((0..4000u64).map(Id).collect());
    let kb = t.memory_bytes() as f64 / 1024.0;
    assert!(kb < 64.0, "{kb} KB (paper: ~36 KB at 6B/entry)");
}
