//! Integration over the PJRT runtime: the AOT artifacts loaded and
//! executed from rust, cross-checked against native implementations and
//! wired into a simulated routing-table snapshot. Skips (loudly) if
//! `make artifacts` has not run.

use d1ht::dht::d1ht::{D1htCfg, D1htSim};
use d1ht::runtime::lookup::{resolve_native, BatchLookup, Snapshot, BATCH};
use d1ht::runtime::{analytics::AnalyticsGrid, artifacts_available};
use d1ht::sim::engine::Queue;
use d1ht::util::rng::Rng;

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("SKIP: artifacts missing — run `make artifacts`");
            return;
        }
    };
}

/// The end-to-end data path: snapshot a *live simulated system's* ground
/// truth, resolve a key batch through the XLA artifact, and verify every
/// answer against both the native search and the 64-bit table.
#[test]
fn xla_lookup_agrees_with_simulated_system() {
    require_artifacts!();
    let mut sim = D1htSim::new(D1htCfg::default());
    let mut q = Queue::new();
    sim.bootstrap(3000, &mut q);
    let snap = Snapshot::capture(sim.truth()).expect("snapshot");
    let exe = BatchLookup::load().expect("artifact");
    let mut rng = Rng::new(99);
    let keys: Vec<u64> = (0..BATCH).map(|_| rng.next_u64()).collect();
    let got = exe.resolve(&snap, &keys).expect("resolve");
    let native = resolve_native(&snap, &keys);
    assert_eq!(got, native, "XLA vs native disagree");
    // all owners are live members
    for owner in got {
        assert!(sim.truth().contains(owner));
    }
}

#[test]
fn analytics_artifact_reproduces_paper_datums() {
    require_artifacts!();
    let grid = AnalyticsGrid::load().expect("artifact");
    // §VIII: n=1e6 at 60/169/174/780 min -> 20.7/7.3/7.1/1.6 kbps
    let pts = [
        (1e6, 60.0 * 60.0, 20.7),
        (1e6, 169.0 * 60.0, 7.3),
        (1e6, 174.0 * 60.0, 7.1),
        (1e6, 780.0 * 60.0, 1.6),
    ];
    let res = grid
        .eval(&pts.iter().map(|p| (p.0, p.1)).collect::<Vec<_>>())
        .expect("eval");
    for (i, &(_, _, want_kbps)) in pts.iter().enumerate() {
        let got = res.d1ht_bps[i] / 1000.0;
        assert!(
            (got - want_kbps).abs() / want_kbps < 0.05,
            "point {i}: {got} vs paper {want_kbps} kbps"
        );
    }
}

#[test]
fn repeated_executions_are_deterministic() {
    require_artifacts!();
    let exe = BatchLookup::load().expect("artifact");
    let mut rng = Rng::new(5);
    let table = d1ht::routing::Table::from_ids(
        (0..1000).map(|_| d1ht::id::Id(rng.next_u64())).collect(),
    );
    let snap = Snapshot::capture(&table).unwrap();
    let keys: Vec<u64> = (0..BATCH).map(|_| rng.next_u64()).collect();
    let a = exe.resolve(&snap, &keys).unwrap();
    let b = exe.resolve(&snap, &keys).unwrap();
    assert_eq!(a, b);
}
