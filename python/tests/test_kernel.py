"""L1 correctness: Pallas ring_search kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the data-path artifact: hypothesis
sweeps table occupancies, duplicates, boundary values, and query
distributions; every case must match ``ref.ring_search_ref`` exactly
(integer indices — no tolerance).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import ring_search as krs

PAD = 0xFFFFFFFF


def make_table(live_ids, table_size=krs.TABLE_SIZE):
    live = np.sort(np.asarray(live_ids, dtype=np.uint32))
    t = np.full(table_size, PAD, dtype=np.uint32)
    t[: len(live)] = live
    return t


def run_kernel(table, queries, **kw):
    out = krs.ring_search(jnp.asarray(table), jnp.asarray(queries), **kw)
    return np.asarray(out)


def run_ref(table, queries):
    return np.asarray(ref.ring_search_ref(jnp.asarray(table), jnp.asarray(queries)))


def pad_queries(qs, batch=krs.BATCH):
    q = np.zeros(batch, dtype=np.uint32)
    q[: len(qs)] = np.asarray(qs, dtype=np.uint32)
    return q


# ---------------------------------------------------------------------------
# Deterministic cases
# ---------------------------------------------------------------------------
class TestRingSearchBasic:
    def test_empty_table_all_wrap(self):
        """All-PAD table: every query lands at index 0 (first PAD slot)."""
        t = make_table([])
        q = pad_queries([0, 1, 123456, PAD - 1])
        assert (run_kernel(t, q) == 0).all()

    def test_single_entry(self):
        t = make_table([1000])
        q = pad_queries([0, 999, 1000, 1001, PAD - 1])
        out = run_kernel(t, q)
        assert list(out[:5]) == [0, 0, 0, 1, 1]

    def test_exact_hits_return_entry(self):
        live = [10, 20, 30, 40]
        t = make_table(live)
        out = run_kernel(t, pad_queries(live))
        assert list(out[:4]) == [0, 1, 2, 3]

    def test_between_entries(self):
        t = make_table([10, 20, 30])
        out = run_kernel(t, pad_queries([11, 19, 21, 29, 31]))
        assert list(out[:5]) == [1, 1, 2, 2, 3]

    def test_duplicates_return_first(self):
        """Lower-bound semantics: first index among equal entries."""
        t = make_table([5, 5, 5, 9])
        out = run_kernel(t, pad_queries([5, 6, 9]))
        assert list(out[:3]) == [0, 3, 3]

    def test_query_zero(self):
        t = make_table([0, 7])
        out = run_kernel(t, pad_queries([0]))
        assert out[0] == 0

    def test_query_above_all_live_wraps(self):
        """Query beyond the last live id resolves to the PAD region == wrap."""
        t = make_table([100, 200])
        out = run_kernel(t, pad_queries([201, PAD - 1]))
        assert out[0] == 2 and out[1] == 2

    def test_full_table_no_padding(self):
        live = np.arange(0, krs.TABLE_SIZE, dtype=np.uint32) * 524288 + 3
        t = make_table(live)
        q = pad_queries([2, 3, 4, int(live[-1]), int(live[-1]) + 1])
        out = run_kernel(t, q)
        assert list(out[:5]) == [0, 0, 1, krs.TABLE_SIZE - 1, krs.TABLE_SIZE]

    def test_matches_numpy_searchsorted(self):
        rng = np.random.default_rng(7)
        live = np.unique(rng.integers(0, PAD, 5000, dtype=np.uint32))
        t = make_table(live)
        q = rng.integers(0, 2**32, krs.BATCH, dtype=np.uint32)
        np.testing.assert_array_equal(
            run_kernel(t, q), np.searchsorted(t, q, side="left").astype(np.int32)
        )

    def test_block_sizes(self):
        """block_q is a tuning knob; results must be identical across it."""
        rng = np.random.default_rng(3)
        t = make_table(rng.integers(0, PAD, 100, dtype=np.uint32))
        q = rng.integers(0, 2**32, krs.BATCH, dtype=np.uint32)
        base = run_kernel(t, q, block_q=256)
        for bq in (64, 128, 512, 1024):
            np.testing.assert_array_equal(run_kernel(t, q, block_q=bq), base)

    def test_bad_block_raises(self):
        t = make_table([1])
        with pytest.raises(ValueError):
            run_kernel(t, np.zeros(krs.BATCH, np.uint32), block_q=300)

    def test_small_table_sizes(self):
        """table_size is static but parametric: cover other powers of two."""
        rng = np.random.default_rng(11)
        for ts in (64, 256, 1024):
            live = np.unique(rng.integers(0, PAD, ts // 2, dtype=np.uint32))
            t = make_table(live, table_size=ts)
            q = rng.integers(0, 2**32, 256, dtype=np.uint32)
            out = krs.ring_search(
                jnp.asarray(t), jnp.asarray(q), table_size=ts, block_q=128
            )
            np.testing.assert_array_equal(
                np.asarray(out), np.searchsorted(t, q, side="left").astype(np.int32)
            )


# ---------------------------------------------------------------------------
# Property-based sweep
# ---------------------------------------------------------------------------
ids32 = st.integers(min_value=0, max_value=PAD - 1)


class TestRingSearchHypothesis:
    @settings(max_examples=30, deadline=None)
    @given(
        live=st.lists(ids32, min_size=0, max_size=300),
        queries=st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=64),
    )
    def test_matches_oracle(self, live, queries):
        t = make_table(live)
        q = pad_queries(queries)
        np.testing.assert_array_equal(run_kernel(t, q), run_ref(t, q))

    @settings(max_examples=20, deadline=None)
    @given(live=st.lists(ids32, min_size=1, max_size=200), data=st.data())
    def test_successor_invariant(self, live, data):
        """table[idx-1] < q <= table[idx] — the lower-bound contract."""
        t = make_table(live)
        q_vals = data.draw(st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=32))
        q = pad_queries(q_vals)
        out = run_kernel(t, q)
        t64 = t.astype(np.uint64)
        for qi, idx in zip(q[: len(q_vals)].astype(np.uint64), out[: len(q_vals)]):
            if idx < krs.TABLE_SIZE:
                assert t64[idx] >= qi
            if idx > 0:
                assert t64[idx - 1] < qi

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_random_dense(self, seed):
        rng = np.random.default_rng(seed)
        n_live = int(rng.integers(0, krs.TABLE_SIZE + 1))
        t = make_table(rng.integers(0, PAD, n_live, dtype=np.uint32))
        q = rng.integers(0, 2**32, krs.BATCH, dtype=np.uint32)
        np.testing.assert_array_equal(run_kernel(t, q), run_ref(t, q))
