"""AOT pipeline: lowering produces loadable, shape-correct HLO text."""

import os

import pytest

from compile import aot, model
from compile.kernels import ring_search as krs


class TestLowering:
    def test_ring_lookup_lowers(self):
        text = aot.lower_entry(model.lookup_entry, model.lookup_shapes())
        assert text.startswith("HloModule")
        assert f"u32[{krs.TABLE_SIZE}]" in text
        assert f"u64[{krs.BATCH}]" in text
        assert f"s32[{krs.BATCH}]" in text

    def test_analytics_lowers(self):
        text = aot.lower_entry(model.analytics_entry, model.analytics_shapes())
        assert text.startswith("HloModule")
        assert f"f32[{model.GRID}]" in text

    def test_no_custom_calls(self):
        """interpret=True pallas must lower to plain HLO — a Mosaic
        custom-call would be unloadable by the CPU PJRT client."""
        for fn, shapes in [
            (model.lookup_entry, model.lookup_shapes()),
            (model.analytics_entry, model.analytics_shapes()),
        ]:
            text = aot.lower_entry(fn, shapes)
            assert "custom-call" not in text, "unrunnable custom-call in HLO"

    def test_entry_layout_is_tuple(self):
        """rust side unwraps with to_tuple{1,2}: root must be a tuple."""
        text = aot.lower_entry(model.lookup_entry, model.lookup_shapes())
        first = text.splitlines()[0]
        assert "->(s32[1024]{0})" in first.replace(" ", "")


class TestBuildTree(object):
    def test_build_writes_all_artifacts(self, tmp_path):
        aot.build(str(tmp_path))
        names = sorted(os.listdir(tmp_path))
        assert names == ["MANIFEST.txt", "analytics.hlo.txt", "ring_lookup.hlo.txt"]
        manifest = (tmp_path / "MANIFEST.txt").read_text()
        assert f"table_size={krs.TABLE_SIZE}" in manifest
        assert f"grid={model.GRID}" in manifest
        for name in ("ring_lookup", "analytics"):
            body = (tmp_path / f"{name}.hlo.txt").read_text()
            assert body.startswith("HloModule")
            assert len(body) > 1000
