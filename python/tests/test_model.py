"""L2 correctness: model graphs vs oracles + the paper's own numbers."""

import math

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import hash as khash
from compile.kernels import ref
from compile.kernels import ring_search as krs

PAD = 0xFFFFFFFF


# ---------------------------------------------------------------------------
# Hash layer
# ---------------------------------------------------------------------------
class TestMix64:
    @settings(max_examples=200, deadline=None)
    @given(st.integers(0, 2**64 - 1))
    def test_matches_scalar_reference(self, x):
        got = int(khash.mix64(jnp.asarray(np.uint64(x))))
        assert got == ref.mix64_ref(x)

    def test_known_vectors(self):
        """Pinned vectors — mirrored in rust/src/id/space.rs unit tests."""
        vectors = {
            0: 0x0,
            1: 0x5692161D100B05E5,
            0xDEADBEEF: 0x4E062702EC929EEA,
            2**64 - 1: 0xB4D055FCF2CBBD7B,
        }
        for x, want in vectors.items():
            assert int(khash.mix64(jnp.asarray(np.uint64(x)))) == want, hex(x)

    def test_bijective_sample(self):
        xs = np.arange(0, 4096, dtype=np.uint64)
        ys = np.asarray(khash.mix64(jnp.asarray(xs)))
        assert len(np.unique(ys)) == len(xs)

    def test_ring32_uniformity(self):
        """Chi-square-ish sanity: 16 buckets over 64k sequential keys."""
        xs = np.arange(0, 1 << 16, dtype=np.uint64)
        ring = np.asarray(khash.key_to_ring32(jnp.asarray(xs)))
        counts = np.bincount(ring >> 28, minlength=16)
        expected = len(xs) / 16
        assert (np.abs(counts - expected) < 0.1 * expected).all()


# ---------------------------------------------------------------------------
# Data path (lookup_resolve == hash + kernel)
# ---------------------------------------------------------------------------
class TestLookupResolve:
    def test_matches_oracle(self):
        rng = np.random.default_rng(42)
        live = np.unique(rng.integers(0, PAD, 2000, dtype=np.uint32))
        t = np.full(krs.TABLE_SIZE, PAD, np.uint32)
        t[: len(live)] = np.sort(live)
        keys = rng.integers(0, 2**63, krs.BATCH, dtype=np.uint64)
        out = model.lookup_entry(jnp.asarray(t), jnp.asarray(keys))[0]
        exp = ref.lookup_resolve_ref(jnp.asarray(t), keys)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))

    def test_output_shape_dtype(self):
        t = jnp.full((krs.TABLE_SIZE,), PAD, jnp.uint32)
        keys = jnp.zeros((krs.BATCH,), jnp.uint64)
        (out,) = model.lookup_entry(t, keys)
        assert out.shape == (krs.BATCH,) and out.dtype == jnp.int32


# ---------------------------------------------------------------------------
# Analytical model — against an independent scalar implementation and the
# paper's reported datums.
# ---------------------------------------------------------------------------
def d1ht_bps_scalar(n, savg, f=0.01, delta=0.25):
    """Scalar float64 re-derivation of Eqs. III.1, IV.2, IV.5-IV.7."""
    r = 2.0 * n / savg
    rho = math.ceil(math.log2(n))
    theta = max((2 * f * savg - 2 * rho * delta) / (8 + rho), 1e-3)
    q = min(2 * r * theta / n, 1 - 1e-9)
    n_msgs = 1.0
    for l in range(1, rho):
        n_msgs += 1.0 - (1.0 - q) ** (2 ** (rho - l - 1))
    return (n_msgs * (model.V_M + model.V_A) + r * model.M_EVENT * theta) / theta


class TestAnalytics:
    def grid(self, n, savg_min):
        nv = jnp.full((model.GRID,), float(n), jnp.float32)
        sv = jnp.full((model.GRID,), savg_min * 60.0, jnp.float32)
        d, c = model.maintenance_grid(nv, sv)
        return float(d[0]), float(c[0])

    def test_paper_fig7_d1ht_datums(self):
        """§VIII: n=1e6 sessions 60/169/174/780 min -> 20.7/7.3/7.1/1.6 kbps."""
        for savg_min, kbps in [(60, 20.7), (169, 7.3), (174, 7.1), (780, 1.6)]:
            d, _ = self.grid(1e6, savg_min)
            assert abs(d / 1000.0 - kbps) / kbps < 0.03, (savg_min, d)

    def test_paper_calot_datum(self):
        """§VIII: 1h-Calot above ~140kbps at n=1e6 KAD (our per-peer form
        gives ~132kbps; see DESIGN.md on the Eq. VII.1 heartbeat typo)."""
        _, c = self.grid(1e6, 169)
        assert 120_000 < c < 150_000

    def test_matches_scalar_float64(self):
        for n in (1e4, 1e5, 1e6, 1e7):
            for savg_min in (60, 169, 174, 780):
                d, _ = self.grid(n, savg_min)
                want = d1ht_bps_scalar(n, savg_min * 60.0)
                assert abs(d - want) / want < 0.02, (n, savg_min, d, want)

    def test_padding_masked(self):
        nv = jnp.zeros((model.GRID,), jnp.float32)
        sv = jnp.full((model.GRID,), 1.0, jnp.float32)
        d, c = model.maintenance_grid(nv, sv)
        assert float(jnp.abs(d).max()) == 0.0 and float(jnp.abs(c).max()) == 0.0

    def test_monotone_in_churn(self):
        """Shorter sessions (more churn) => more bandwidth, both systems."""
        d_fast, c_fast = self.grid(1e6, 60)
        d_slow, c_slow = self.grid(1e6, 780)
        assert d_fast > d_slow and c_fast > c_slow

    def test_d1ht_beats_calot_at_scale(self):
        """The paper's headline: ~order-of-magnitude reduction for big n."""
        for n in (1e5, 1e6, 1e7):
            d, c = self.grid(n, 174)
            assert c / d > 5.0, (n, d, c)
