"""Build-time compile package (L1 Pallas kernels + L2 JAX model + AOT).

x64 must be enabled before any jax array is created: the data path hashes
64-bit keys (kernels/hash.py) and the default jax config silently downcasts
uint64 -> uint32, which would corrupt the key space.
"""

import jax

jax.config.update("jax_enable_x64", True)
