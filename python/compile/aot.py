"""AOT lowering: JAX (L2 + L1) -> HLO *text* artifacts for the rust runtime.

HLO text — NOT ``lowered.compile().serialize()`` and NOT a serialized
HloModuleProto — is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids which xla_extension 0.5.1 (the version behind the
published ``xla`` 0.1.6 crate) rejects (``proto.id() <= INT_MAX``).  The XLA
text parser reassigns ids, so text round-trips cleanly.  See
/opt/xla-example/README.md and /opt/xla-example/gen_hlo.py.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
Produces:
  artifacts/ring_lookup.hlo.txt   lookup_resolve  (u32[8192] table, u64[1024] keys) -> i32[1024]
  artifacts/analytics.hlo.txt     maintenance_grid (f32[64] n, f32[64] savg) -> (f32[64], f32[64])
  artifacts/MANIFEST.txt          shapes + provenance, parsed by rust tests
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ring_search as krs


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, shapes) -> str:
    return to_hlo_text(jax.jit(fn).lower(*shapes))


ARTIFACTS = {
    # name -> (entry fn, example-shape fn, human signature)
    "ring_lookup": (
        model.lookup_entry,
        model.lookup_shapes,
        f"(u32[{krs.TABLE_SIZE}] table, u64[{krs.BATCH}] keys) -> (i32[{krs.BATCH}],)",
    ),
    "analytics": (
        model.analytics_entry,
        model.analytics_shapes,
        f"(f32[{model.GRID}] n, f32[{model.GRID}] savg_sec) -> (f32[{model.GRID}] d1ht_bps, f32[{model.GRID}] calot_bps)",
    ),
}


def build(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest = [
        "# d1ht AOT artifacts — HLO text (see python/compile/aot.py)",
        f"jax={jax.__version__}",
        f"table_size={krs.TABLE_SIZE}",
        f"batch={krs.BATCH}",
        f"grid={model.GRID}",
        f"pad=0x{0xFFFFFFFF:08X}",
    ]
    for name, (fn, shapes_fn, sig) in ARTIFACTS.items():
        text = lower_entry(fn, shapes_fn())
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"{name}: {sig}")
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "MANIFEST.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None,
                    help="legacy single-file mode: also copy ring_lookup to this path")
    args = ap.parse_args()
    out_dir = (os.path.dirname(args.out) or ".") if args.out and not args.out_dir else args.out_dir
    build(out_dir)
    if args.out:
        # Makefile compatibility: artifacts/model.hlo.txt = the data-path graph.
        src = os.path.join(out_dir, "ring_lookup.hlo.txt")
        with open(src) as f, open(args.out, "w") as g:
            g.write(f.read())
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
