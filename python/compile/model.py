"""L2: the paper's compute graphs, authored in JAX, calling the L1 kernels.

Two AOT entry points (lowered to HLO text once by ``aot.py``, executed from
rust via PJRT — python is never on the request path):

* ``lookup_resolve`` — the DHT data path: mix a batch of 64-bit keys onto
  the u32 ring and successor-search them against a padded routing-table
  snapshot with the Pallas kernel.  This is what the rust coordinator calls
  to resolve lookup batches (rust/src/runtime/lookup.rs).

* ``maintenance_grid`` — the paper's analytical maintenance-bandwidth model
  (Eqs. III.1, IV.2, IV.5–IV.7 for D1HT; Eq. VII.1 for 1h-Calot) evaluated
  vectorized over a (system size, average session length) grid.  The Fig. 7
  bench executes this artifact from rust and cross-checks the native
  implementation in rust/src/analysis/.

Shapes are static (AOT): see TABLE_SIZE/BATCH in kernels/ring_search.py and
GRID here; they must match rust/src/runtime/{lookup,analytics}.rs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import hash as khash
from .kernels import ring_search as krs

# ---------------------------------------------------------------------------
# Wire-format constants — single source of truth is Fig. 2 of the paper;
# mirrored in rust/src/proto/sizes.rs (bits, IPv4+UDP headers included).
# ---------------------------------------------------------------------------
V_M = 320.0   # D1HT/OneHop maintenance-message fixed part
V_A = 288.0   # acknowledgment
V_H = 288.0   # 1h-Calot heartbeat
V_C = 384.0   # 1h-Calot maintenance message (carries exactly one event)
M_EVENT = 32.0  # bits per event (IPv4, default port)

# Analytical grid size (padded by the rust caller; mask = n > 0).
GRID = 64
MAX_RHO = 24  # ceil(log2(1e7)) = 24; static unroll bound for the P(l) sum


# ---------------------------------------------------------------------------
# Data path
# ---------------------------------------------------------------------------
def lookup_resolve(table: jax.Array, keys: jax.Array) -> jax.Array:
    """Resolve a batch of 64-bit keys against a routing-table snapshot.

    Args:
      table: (TABLE_SIZE,) uint32 sorted ring ids, PAD-padded tail.
      keys:  (BATCH,) uint64 keys (pre-hash).

    Returns:
      (BATCH,) int32 successor indices (TABLE_SIZE => wrap to slot 0).
    """
    ring = khash.key_to_ring32(keys)
    return krs.ring_search(table, ring)


# ---------------------------------------------------------------------------
# Analytical maintenance model (per-peer outgoing bandwidth, bits/sec)
# ---------------------------------------------------------------------------
def d1ht_bandwidth(n: jax.Array, savg_sec: jax.Array, *,
                   f: float = 0.01, delta_avg: float = 0.25) -> jax.Array:
    """Eq. IV.5 with Theta from Eq. IV.2 (explicit message delay).

    n: system size; savg_sec: average session length in seconds.
    Returns per-peer maintenance bandwidth in bits/sec.
    """
    n = n.astype(jnp.float32)
    savg = savg_sec.astype(jnp.float32)
    r = 2.0 * n / savg                                   # Eq. III.1
    rho = jnp.ceil(jnp.log2(jnp.maximum(n, 2.0)))        # messages per interval
    theta = (2.0 * f * savg - 2.0 * rho * delta_avg) / (8.0 + rho)  # Eq. IV.2
    theta = jnp.maximum(theta, 1e-3)

    # P(l) = 1 - (1 - 2 r Theta / n)^(2^(rho-l-1)),  l in [1, rho)  (Eq. IV.6)
    # computed as exp(k * log1p(-q)) for numerical stability at huge k.
    q = jnp.clip(2.0 * r * theta / n, 0.0, 1.0 - 1e-7)
    log1mq = jnp.log1p(-q)
    n_msgs = jnp.ones_like(n)                            # TTL=0 always sent
    for l in range(1, MAX_RHO):
        k = jnp.exp2(rho - l - 1.0)
        p_l = 1.0 - jnp.exp(k * log1mq)
        n_msgs = n_msgs + jnp.where(l < rho, p_l, 0.0)   # Eq. IV.7

    return (n_msgs * (V_M + V_A) + r * M_EVENT * theta) / theta  # Eq. IV.5


def calot_bandwidth(n: jax.Array, savg_sec: jax.Array) -> jax.Array:
    """Eq. VII.1, per peer.

    Note (DESIGN.md §6): the paper prints the heartbeat term as
    ``4·n·v_h/60``; dimensional analysis and the paper's own ">140 kbps at
    n=1e6, KAD" datum require the *per-peer* term ``4·v_h/60`` (each peer
    sends four heartbeats per minute).  We implement the per-peer form.
    """
    n = n.astype(jnp.float32)
    r = 2.0 * n / savg_sec.astype(jnp.float32)
    return r * (V_C + V_A) + 4.0 * V_H / 60.0


def maintenance_grid(n: jax.Array, savg_sec: jax.Array):
    """Vectorized (GRID,) evaluation for the Fig. 7 sweep.

    Returns (d1ht_bps, calot_bps); entries where n <= 0 are 0 (padding).
    """
    live = n > 0
    d = jnp.where(live, d1ht_bandwidth(n, savg_sec), 0.0)
    c = jnp.where(live, calot_bandwidth(n, savg_sec), 0.0)
    return d, c


# ---------------------------------------------------------------------------
# AOT wrappers with pinned shapes (used by aot.py)
# ---------------------------------------------------------------------------
def lookup_entry(table, keys):
    return (lookup_resolve(table, keys),)


def analytics_entry(n, savg_sec):
    d, c = maintenance_grid(n, savg_sec)
    return (d, c)


def lookup_shapes():
    return (
        jax.ShapeDtypeStruct((krs.TABLE_SIZE,), jnp.uint32),
        jax.ShapeDtypeStruct((krs.BATCH,), jnp.uint64),
    )


def analytics_shapes():
    return (
        jax.ShapeDtypeStruct((GRID,), jnp.float32),
        jax.ShapeDtypeStruct((GRID,), jnp.float32),
    )
