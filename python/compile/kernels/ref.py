"""Pure-jnp correctness oracles for the L1 kernels.

Everything here is deliberately naive and obviously-correct; pytest compares
the Pallas kernels (and the AOT'd HLO, via the rust integration tests)
against these.
"""

from __future__ import annotations

import jax.numpy as jnp


def ring_search_ref(table: jnp.ndarray, queries: jnp.ndarray) -> jnp.ndarray:
    """Index of the first ``table`` entry >= query (successor semantics).

    ``table`` is sorted ascending (PAD-padded tail).  Equivalent to
    ``jnp.searchsorted(table, q, side='left')`` per query; written as an
    explicit comparison-sum so it is independent of searchsorted's
    implementation (and trivially correct for duplicate entries: it returns
    the *first* index among equals, matching the kernel's lower-bound
    invariant).
    """
    # count of entries strictly below q == index of first entry >= q
    return jnp.sum(table[None, :] < queries[:, None], axis=1).astype(jnp.int32)


def mix64_ref(x):
    """Scalar-python SplitMix64 finalizer (ground truth for hash.mix64)."""
    mask = (1 << 64) - 1
    x = int(x) & mask
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & mask
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & mask
    return (x ^ (x >> 31)) & mask


def lookup_resolve_ref(table, keys):
    """Oracle for model.lookup_resolve: hash keys then successor-search."""
    ring = jnp.array([mix64_ref(k) >> 32 for k in list(keys)], dtype=jnp.uint32)
    return ring_search_ref(table, ring)
