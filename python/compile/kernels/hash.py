"""Key-to-ring hashing, vectorized (L2 helper, also used inside the model).

The paper derives IDs from SHA-1 of key values / peer addresses (§III).  On
the AOT data path we hash *already-64-bit* keys onto the 32-bit kernel ring
with a strong integer mixer (SplitMix64 finalizer, Stafford variant 13).
This preserves the paper's modeling assumption — lookup targets uniformly
distributed over the ring, oblivious to peer IDs — which is all the
consistent-hashing analysis needs.  Full SHA-1 identity derivation lives on
the rust side (rust/src/id/sha1.rs) where peer addresses are available.

The rust mirror of this function is rust/src/id/space.rs::mix64; the two are
bit-for-bit identical and cross-checked by python/tests/test_model.py
vectors embedded in rust/src/id/space.rs tests.
"""

from __future__ import annotations

import jax.numpy as jnp

M1 = jnp.uint64(0xBF58476D1CE4E5B9)
M2 = jnp.uint64(0x94D049BB133111EB)


def mix64(x: jnp.ndarray) -> jnp.ndarray:
    """SplitMix64 finalizer: uniform 64-bit mixing, bijective."""
    x = x.astype(jnp.uint64)
    x = (x ^ (x >> jnp.uint64(30))) * M1
    x = (x ^ (x >> jnp.uint64(27))) * M2
    x = x ^ (x >> jnp.uint64(31))
    return x


def key_to_ring32(key: jnp.ndarray) -> jnp.ndarray:
    """Map 64-bit keys to the kernel's u32 ring: top 32 bits of the mix.

    The top bits of SplitMix64 pass PractRand; taking them (rather than a
    modulo) keeps the map monotone-free and avoids the PAD value except with
    probability 2^-32 per key (the rust side re-bucketizes those).
    """
    return (mix64(key) >> jnp.uint64(32)).astype(jnp.uint32)
