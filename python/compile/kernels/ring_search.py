"""L1 Pallas kernel: batched ring-successor search.

The compute hot-spot of a single-hop DHT's data path is resolving a batch of
lookups against the full routing table: for each queried ring ID, find the
first table entry clockwise from it (the *successor*, Chord/D1HT semantics,
Section III of the paper).

The routing table is a sorted array of ``table_size`` u32 ring IDs, padded at
the tail with ``PAD`` (0xFFFFFFFF).  For a query ``q`` the kernel returns the
index of the first entry ``>= q``; callers wrap index ``n_live`` (the number
of live entries) back to slot 0, which implements the ring wrap-around.

TPU mapping (see DESIGN.md §Hardware-Adaptation):
  * the whole table is one VMEM block (8192 x u32 = 32 KiB, the paper itself
    reports ~36 KB routing tables) — no HBM traffic inside the search;
  * queries stream through in ``block_q`` chunks via BlockSpec;
  * the search is a fixed-depth (log2 table_size) *branchless* binary search
    expressed as vectorized compare/select steps — pure VPU work, no MXU,
    no data-dependent control flow, identical instruction stream per lane.

``interpret=True`` is mandatory in this image: the CPU PJRT plugin cannot
execute Mosaic custom-calls.  Numerics are validated against the pure-jnp
oracle in ``ref.py`` by ``python/tests/test_kernel.py``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Padding value for unused table slots.  Must compare greater than any live
# id; live ids are restricted to [0, PAD) by the rust side.
PAD = jnp.uint32(0xFFFFFFFF)

# Default AOT shapes (must match rust/src/runtime/lookup.rs).
TABLE_SIZE = 8192
BATCH = 1024


def _search_kernel(table_ref, query_ref, out_ref, *, table_size: int):
    """One grid step: successor-search ``query_ref`` against ``table_ref``.

    Branchless binary search: maintain per-lane lower bound ``lo`` such that
    table[lo-1] < q <= table[lo] at exit.  ``depth`` iterations of
    compare+select, fully unrolled (depth = log2(table_size) = 13 for the
    default shape), each a vector op over the whole query block.
    """
    queries = query_ref[...]
    depth = int(math.log2(table_size))
    assert 1 << depth == table_size, "table_size must be a power of two"

    lo = jnp.zeros(queries.shape, dtype=jnp.int32)
    # Invariant: the answer is in [lo, lo + 2^k] after (depth - k) steps.
    for k in reversed(range(depth)):
        mid = lo + (1 << k)
        # Gather table[mid - 1]: the largest element strictly below the
        # candidate upper half.  mid is in [1, table_size], so mid-1 indexes
        # safely.  One gather + compare + select per step.
        pivot = table_ref[...][mid - 1]
        lo = jnp.where(pivot < queries, mid, lo)
    # The loop clamps lo to table_size-1; if even the last entry is below
    # the query the true lower bound is table_size ("wrap to slot 0").
    last = table_ref[...][lo]
    out_ref[...] = jnp.where(last < queries, lo + 1, lo)


@functools.partial(jax.jit, static_argnames=("table_size", "block_q"))
def ring_search(table: jax.Array, queries: jax.Array, *,
                table_size: int = TABLE_SIZE, block_q: int = 256) -> jax.Array:
    """Batched successor search: index of first ``table`` entry >= query.

    Args:
      table:   sorted ``(table_size,)`` uint32, tail-padded with ``PAD``.
      queries: ``(batch,)`` uint32 ring ids to resolve.
      table_size: static table length (power of two).
      block_q: query block per grid step (must divide batch).

    Returns:
      ``(batch,)`` int32 indices in ``[0, table_size]``; ``table_size`` (or
      any index >= n_live) means "wraps to slot 0".
    """
    (batch,) = queries.shape
    if batch % block_q:
        raise ValueError(f"batch {batch} not divisible by block_q {block_q}")
    grid = (batch // block_q,)
    return pl.pallas_call(
        functools.partial(_search_kernel, table_size=table_size),
        grid=grid,
        # The table is re-presented whole to every grid step (one VMEM
        # block); queries/outputs are tiled along the batch.
        in_specs=[
            pl.BlockSpec((table_size,), lambda i: (0,)),
            pl.BlockSpec((block_q,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_q,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((batch,), jnp.int32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(table, queries)
